"""Quickstart: CP decomposition with communication-optimal MTTKRP.

Decomposes a synthetic low-rank tensor with CP-ALS through three MTTKRP
backends — einsum, the explicit-Khatri-Rao matmul baseline (what the paper
beats), and the Pallas blocked kernel (Algorithm 2 on TPU; interpret mode
here) — and prints the paper's communication accounting for each.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import bounds, cp_als
from repro.core.krp import mttkrp_via_matmul
from repro.core.mttkrp import mttkrp
from repro.core.tensor import random_low_rank_tensor
from repro.kernels.ops import mttkrp_pallas


def main():
    dims, rank = (48, 40, 32), 6
    print(f"tensor {dims}, CP rank {rank}")
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)

    backends = {
        "einsum": mttkrp,
        "krp_matmul_baseline": mttkrp_via_matmul,
        "pallas_blocked_alg2": lambda t, f, n: mttkrp_pallas(
            t, f, n, interpret=True
        ),
    }
    for name, fn in backends.items():
        res = cp_als(x, rank, n_iters=12, key=jax.random.PRNGKey(1),
                     mttkrp_fn=fn)
        print(f"  backend={name:22s} fit={res.final_fit:.5f}")

    # the paper's sequential communication accounting: pick a fast memory
    # far smaller than the tensor so blocking matters (M = 4096 words)
    mem = 4096
    b = bounds.best_block_size(dims, mem)
    print("\nsequential model (fast memory M = %d words):" % mem)
    print(f"  lower bound (Thm 4.1 / Fact 4.1): "
          f"{bounds.seq_lb(dims, rank, mem):,.0f} words")
    print(f"  Algorithm 2 (blocked, b={b}):      "
          f"{bounds.seq_blocked_cost(dims, rank, b):,.0f} words")
    print(f"  Algorithm 1 (unblocked):          "
          f"{bounds.seq_unblocked_cost(dims, rank):,.0f} words")
    print(f"  matmul baseline (§VI-A):          "
          f"{bounds.matmul_seq_cost(dims, rank, mem):,.0f} words")

    # --- autotuning: backend="auto" -------------------------------------
    # The analytic model above has machine-independent constants; the
    # autotuner measures candidate plans on THIS machine, persists the
    # winner in a plan cache, and replays it on every later call. (The
    # cache normally lives at ~/.cache/repro-mttkrp/plans.json /
    # $REPRO_TUNE_CACHE; the demo redirects it to a throwaway file and
    # restores the env afterwards.)
    from repro.engine import execute
    from repro.tune.cache import isolated_cache
    from repro.tune.search import resolve, tune_mttkrp

    with isolated_cache():
        factors = [jax.random.normal(jax.random.PRNGKey(k), (d, rank))
                   for k, d in enumerate(dims)]
        res = tune_mttkrp(x, factors, 0, interpret=True)  # cold: search once
        print(f"\nautotuner winner: {res.winner.label} "
              f"(metric={res.metric}, {len(res.measurements)} candidates)")
        r = resolve(dims, rank, 0, x.dtype, None)         # warm: cache hit
        print(f"  warm cache hit={r.cache_hit} -> backend={r.backend}")
        b = execute.mttkrp(x, factors, 0, backend="auto")  # replays winner
        print(f"  mttkrp(backend='auto') -> {b.shape}; later sessions "
              f"replay the tuned plan from the cache, no re-search")


if __name__ == "__main__":
    main()
