"""Quickstart: CP decomposition with communication-optimal MTTKRP.

Context-first API: ONE immutable ``repro.ExecutionContext`` carries the
full execution environment (backend, memory descriptor, interpret mode,
tuning policy) and drives every driver. Decomposes a synthetic low-rank
tensor with CP-ALS through three engine backends — einsum, the explicit
Khatri-Rao matmul baseline (what the paper beats), and the Pallas blocked
kernel (Algorithm 2 on TPU; interpret mode here) — prints the paper's
communication accounting, then autotunes and shows the tuned setup
round-tripping through JSON as a reproducible artifact.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

import repro
from repro.core import bounds
from repro.core.krp import mttkrp_via_matmul
from repro.core.tensor import random_low_rank_tensor


def main():
    dims, rank = (48, 40, 32), 6
    print(f"tensor {dims}, CP rank {rank}")
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)

    # one context per backend; the same ctx drives every MTTKRP of the run
    contexts = {
        "einsum": repro.ExecutionContext.create(backend="einsum"),
        "pallas_blocked_alg2": repro.ExecutionContext.create(
            backend="pallas", interpret=True
        ),
    }
    for name, ctx in contexts.items():
        res = repro.cp_als(
            x, rank, n_iters=12, key=jax.random.PRNGKey(1), ctx=ctx
        )
        print(f"  backend={name:22s} fit={res.final_fit:.5f}")
    # a custom mttkrp_fn still overrides the engine (the paper's §VI-A
    # matmul baseline is not an engine backend)
    res = repro.cp_als(x, rank, n_iters=12, key=jax.random.PRNGKey(1),
                       mttkrp_fn=mttkrp_via_matmul)
    print(f"  backend={'krp_matmul_baseline':22s} fit={res.final_fit:.5f}")

    # the paper's sequential communication accounting: pick a fast memory
    # far smaller than the tensor so blocking matters (M = 4096 words)
    mem = 4096
    b = bounds.best_block_size(dims, mem)
    print("\nsequential model (fast memory M = %d words):" % mem)
    print(f"  lower bound (Thm 4.1 / Fact 4.1): "
          f"{bounds.seq_lb(dims, rank, mem):,.0f} words")
    print(f"  Algorithm 2 (blocked, b={b}):      "
          f"{bounds.seq_blocked_cost(dims, rank, b):,.0f} words")
    print(f"  Algorithm 1 (unblocked):          "
          f"{bounds.seq_unblocked_cost(dims, rank):,.0f} words")
    print(f"  matmul baseline (§VI-A):          "
          f"{bounds.matmul_seq_cost(dims, rank, mem):,.0f} words")

    # --- autotuning: backend="auto" -------------------------------------
    # The analytic model above has machine-independent constants; the
    # autotuner measures candidate plans on THIS machine, persists the
    # winner in a plan cache, and replays it on every later call. (The
    # cache normally lives at ~/.cache/repro-mttkrp/plans.json /
    # $REPRO_TUNE_CACHE; the demo redirects it to a throwaway file and
    # restores the env afterwards.)
    from repro.tune.cache import isolated_cache
    from repro.tune.search import tune_mttkrp

    with isolated_cache():
        factors = [jax.random.normal(jax.random.PRNGKey(k), (d, rank))
                   for k, d in enumerate(dims)]
        res = tune_mttkrp(x, factors, 0, interpret=True)  # cold: search once
        print(f"\nautotuner winner: {res.winner.label} "
              f"(metric={res.metric}, {len(res.measurements)} candidates)")
        # for_problem pins every "auto" decision (one per mode) eagerly —
        # drivers REPLAY them instead of re-resolving per call
        ctx = repro.ExecutionContext.for_problem(dims, rank, backend="auto")
        print("  pinned decisions:",
              [(d.mode, d.backend, d.cache_hit) for d in ctx.decisions])
        b0 = repro.mttkrp(x, factors, 0, ctx=ctx)  # replays the winner
        print(f"  mttkrp(ctx) -> {b0.shape}")
        # the tuned, validated setup is a portable artifact: JSON
        # round-trip reproduces the identical plan resolutions anywhere
        ctx2 = repro.ExecutionContext.from_json(ctx.to_json())
        assert ctx2 == ctx and ctx2.decisions == ctx.decisions
        print(f"  to_json/from_json round-trip OK "
              f"({len(ctx.to_json())} bytes); set REPRO_CONTEXT or pass "
              f"benchmarks/run.py --context to replay it")


if __name__ == "__main__":
    main()
