"""QUARANTINED SEED SCAFFOLDING — not part of the paper reproduction.

This LM-training driver (and the `repro.models` / `repro.training` /
`repro.launch` stack it exercises) came with the repo seed and is
unrelated to the MTTKRP/Multi-TTM communication-bounds work; it is kept
only to avoid churn. It is not documented in README's examples, not
CI-smoked, and nothing in the paper stack imports it. See README.md
§"Paper-relevant vs. seed leftovers".

End-to-end training driver: a ~100M-parameter qwen2-family model on the
synthetic bigram corpus, with the full substrate (microbatched step, AdamW,
async checkpointing, restart recovery, straggler monitor).

Demo (CPU-sized, ~2 min):
    PYTHONPATH=src python examples/train_lm.py

The full deliverable run (~100M params, a few hundred steps — hours on this
1-core CPU container, minutes on one accelerator host):
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.data import DataConfig
from repro.models.sharding import NULL
from repro.optim.schedule import cosine_schedule
from repro.training import LoopConfig, TrainLoop, init_train_state
from repro.training.steps import build_train_step


def model_config(full: bool):
    base = get_config("qwen2-1.5b")
    if full:
        # ~100M params: 12 layers, d=512, ff=2048, 32k vocab
        return dataclasses.replace(
            base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
            head_dim=64, d_ff=2048, vocab_size=32000,
        )
    # CPU demo: ~5M params
    return dataclasses.replace(
        base, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    steps = args.steps or (300 if args.full else 60)

    cfg = model_config(args.full)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} params={n / 1e6:.1f}M")

    step = jax.jit(
        build_train_step(
            cfg, NULL, microbatches=2,
            lr_fn=lambda s: cosine_schedule(s, 1e-3, 20, steps),
        )
    )
    data = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
    )
    loop = TrainLoop(
        step, data,
        LoopConfig(total_steps=steps, ckpt_every=max(steps // 4, 1),
                   ckpt_dir=args.ckpt_dir),
    )
    t0 = time.time()
    state, stats = loop.run(state)
    dt = time.time() - t0
    k = max(len(stats.losses) // 10, 1)
    smoothed = [
        sum(stats.losses[i: i + k]) / len(stats.losses[i: i + k])
        for i in range(0, len(stats.losses), k)
    ]
    print("loss trajectory:", " -> ".join(f"{v:.3f}" for v in smoothed))
    print(
        f"{stats.steps_done} steps in {dt:.0f}s "
        f"({dt / max(stats.steps_done, 1):.2f}s/step); "
        f"restarts={stats.restarts}; checkpoints in {args.ckpt_dir}"
    )
    assert stats.losses[-1] < stats.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
