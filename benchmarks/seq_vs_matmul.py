"""Benchmark: §VI-A — Algorithm 2 vs MTTKRP-via-matmul communication.

Two regimes: R = O(sqrt(M)) (tensor-dominated, both approaches ~equal) and
NR = Ω(M^{1-1/N}) (factor-dominated: Alg 2 wins by ~M^{1/2-1/N}/N).
"""

from __future__ import annotations

import math
import time

from repro.core import bounds

CASES = [
    # (dims, mem, rank) spanning the two §VI-A regimes
    ((1024, 1024, 1024), 2 ** 20, 64),       # R < sqrt(M): tensor-dominated
    ((1024, 1024, 1024), 2 ** 20, 1024),     # R = sqrt(M): boundary
    ((1024, 1024, 1024), 2 ** 20, 16384),    # NR >> M^{2/3}: factor-dominated
    ((4096, 4096, 4096), 2 ** 24, 131072),   # deep factor-dominated
]


def rows() -> list[tuple[str, float, str]]:
    out = []
    for dims, mem, rank in CASES:
        t0 = time.perf_counter()
        n = len(dims)
        b = bounds.best_block_size(dims, mem)
        alg2 = bounds.seq_blocked_cost(dims, rank, b)
        mm = bounds.matmul_seq_cost(dims, rank, mem)
        dt = (time.perf_counter() - t0) * 1e6
        regime = (
            "tensor" if rank <= math.sqrt(mem)
            else ("factor" if n * rank >= mem ** (1 - 1 / n) else "mid")
        )
        predicted = mem ** (0.5 - 1 / n) / n
        name = f"seq_vs_matmul[R{rank},M{mem}]"
        derived = (
            f"regime={regime};alg2_words={alg2:.3g};matmul_words={mm:.3g};"
            f"matmul/alg2={mm / alg2:.2f};paper_predicted_factor="
            f"{predicted:.1f}"
        )
        out.append((name, dt, derived))
    return out
