"""Benchmark: the Pallas blocked-MTTKRP kernel (TPU Algorithm 2).

interpret-mode correctness timing vs the jnp oracle, plus the kernel's
modeled HBM traffic against the paper's Eq (10) and the tensor-size floor
(this container is CPU-only; on TPU the same harness reports wall time).
All planning/traffic numbers come from the engine planner — the same
BlockPlan object the kernel executes.
"""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.engine import choose_blocks, mttkrp
from repro.kernels.ref import mttkrp_ref

CASES = [
    ((64, 64, 64), 16),
    ((128, 32, 64), 8),
    ((32, 32, 32, 16), 8),
]


def rows() -> list[tuple[str, float, str]]:
    out = []
    key = jax.random.PRNGKey(0)
    for dims, rank in CASES:
        kx, *kf = jax.random.split(key, len(dims) + 1)
        x = jax.random.normal(kx, dims, jnp.float32)
        fs = [
            jax.random.normal(k, (d, rank), jnp.float32)
            for k, d in zip(kf, dims)
        ]
        from repro import ExecutionContext

        pal_ctx = ExecutionContext.create(backend="pallas", interpret=True)
        t0 = time.perf_counter()
        got = mttkrp(x, fs, 0, ctx=pal_ctx)
        jax.block_until_ready(got)
        dt = (time.perf_counter() - t0) * 1e6
        ref = mttkrp_ref(x, fs, 0)
        err = float(jnp.max(jnp.abs(got - ref)))
        plan = choose_blocks(dims, rank)
        traffic = plan.traffic_model(dims, rank)
        tensor_bytes = math.prod(dims) * 4
        # paper ideal for VMEM-sized fast memory
        m_words = 8 * 2 ** 20 // 4
        lb = bounds.seq_lb(dims, rank, m_words) * 4
        name = f"kernel_mttkrp[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"maxerr={err:.2e};plan={plan.block_i}x"
            f"{'x'.join(map(str, plan.block_contract))}xR{plan.block_r};"
            f"modeled_bytes={traffic['total_bytes']};"
            f"eq10_bytes={traffic['eq10_bytes']};"
            f"tensor_bytes={tensor_bytes};lb_bytes={lb:.0f};"
            f"traffic/tensor={traffic['total_bytes'] / tensor_bytes:.2f}"
        )
        out.append((name, dt, derived))
    return out
