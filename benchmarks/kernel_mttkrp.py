"""Benchmark: the Pallas blocked-MTTKRP kernel (TPU Algorithm 2).

interpret-mode correctness timing vs the jnp oracle, plus the kernel's
modeled HBM traffic against the paper's Eq (10) and the tensor-size floor
(this container is CPU-only; on TPU the same harness reports wall time).
"""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.kernels.ops import choose_blocks, mttkrp_pallas, mttkrp_traffic_model
from repro.kernels.ref import mttkrp_ref

CASES = [
    ((64, 64, 64), 16),
    ((128, 32, 64), 8),
    ((32, 32, 32, 16), 8),
]


def rows() -> list[tuple[str, float, str]]:
    out = []
    key = jax.random.PRNGKey(0)
    for dims, rank in CASES:
        kx, *kf = jax.random.split(key, len(dims) + 1)
        x = jax.random.normal(kx, dims, jnp.float32)
        fs = [
            jax.random.normal(k, (d, rank), jnp.float32)
            for k, d in zip(kf, dims)
        ]
        t0 = time.perf_counter()
        got = mttkrp_pallas(x, fs, 0, interpret=True)
        jax.block_until_ready(got)
        dt = (time.perf_counter() - t0) * 1e6
        ref = mttkrp_ref(x, fs, 0)
        err = float(jnp.max(jnp.abs(got - ref)))
        plan = choose_blocks(dims, rank)
        traffic = mttkrp_traffic_model(dims, rank, plan)
        tensor_bytes = math.prod(dims) * 4
        # paper ideal for VMEM-sized fast memory
        m_words = 8 * 2 ** 20 // 4
        lb = bounds.seq_lb(dims, rank, m_words) * 4
        name = f"kernel_mttkrp[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"maxerr={err:.2e};plan={plan.block_i}x"
            f"{'x'.join(map(str, plan.block_contract))}xR{plan.block_r};"
            f"modeled_bytes={traffic['total_bytes']};"
            f"tensor_bytes={tensor_bytes};"
            f"traffic/tensor={traffic['total_bytes'] / tensor_bytes:.2f}"
        )
        out.append((name, dt, derived))
    return out
