"""Benchmark: §VI-B — parallel MTTKRP communication across P and both
NR regimes: Alg 3 (stationary), Alg 4 (general, optimal P0), the Cor 4.2
lower bound, and the matmul baseline.

Analytic per-processor words from the paper's cost expressions, with the
grid chooser solving the integer factorization exactly. Set
REPRO_BENCH_MEASURE=1 to additionally verify Alg 3/4 bytes against compiled
shard_map HLO on 8 host devices (subprocess; slower — the same check runs
in tests/test_distributed.py).
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

from repro.core import bounds
from repro.core.grid import optimal_grid, stationary_grid

SWEEP_P = (16, 64, 256, 512, 4096)
CASES = [
    ((4096, 4096, 4096), 16),     # small NR: stationary regime
    ((256, 256, 256), 65536),     # large NR: rank-partitioned (P0 > 1)
    ((256, 1024, 65536), 64),     # skewed dims
]


def rows() -> list[tuple[str, float, str]]:
    out = []
    for dims, rank in CASES:
        for procs in SWEEP_P:
            t0 = time.perf_counter()
            g3 = stationary_grid(dims, procs)
            c3 = bounds.par_stationary_cost(dims, rank, g3)
            p0, g4 = optimal_grid(dims, rank, procs)
            c4 = bounds.par_general_cost(dims, rank, g4, p0)
            lb = max(
                bounds.par_lb_general(dims, rank, procs),
                bounds.par_lb_stationary(dims, rank, procs),
                0.0,
            )
            mm = bounds.matmul_par_cost(dims, rank, procs)
            dt = (time.perf_counter() - t0) * 1e6
            regime = bounds.nr_threshold_regime(dims, rank, procs)
            name = f"par_comm[R{rank},P{procs}]"
            derived = (
                f"regime={regime};p0={p0};alg3={c3:.3g};alg4={c4:.3g};"
                f"lb={lb:.3g};matmul={mm:.3g};"
                f"alg4/lb={(c4 / lb if lb > 0 else float('inf')):.2f};"
                f"matmul/alg4={mm / max(c4, 1e-9):.2f}"
            )
            out.append((name, dt, derived))
    if os.environ.get("REPRO_BENCH_MEASURE"):
        out.append(_measured_row())
    return out


def _measured_row() -> tuple[str, float, str]:
    worker = os.path.join(
        os.path.dirname(__file__), "..", "tests", "dist_worker.py"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, worker, "check_comm_matches_eq12",
         "check_comm_matches_eq16"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    dt = (time.perf_counter() - t0) * 1e6
    ok = proc.returncode == 0 and "ALL_DIST_OK" in proc.stdout
    return (
        "par_comm[measured_hlo_vs_eq12_eq16]",
        dt,
        f"exact_match={'yes' if ok else 'NO'}",
    )
