"""Benchmark: sequential blocked MTTKRP vs unblocked vs lower bounds.

Reproduces the paper's Thm 6.1 claim operationally: the two-level-memory
simulator executes Algorithms 1 and 2 and counts every word moved; the
blocked algorithm attains the max(Thm 4.1, Fact 4.1) lower bound within a
small constant while the unblocked one does not.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import bounds
from repro.core.simulator import simulate_blocked
from repro.engine.plan import Memory, best_uniform_block

CASES = [
    # (dims, rank, mem)
    ((24, 24, 24), 16, 512),
    ((24, 24, 24), 16, 2048),
    ((32, 32, 32), 8, 1024),
    ((16, 32, 64), 8, 1024),
    ((12, 12, 12, 12), 6, 4096),
]


def rows() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []
    for dims, rank, mem in CASES:
        x = rng.standard_normal(dims)
        fs = [rng.standard_normal((d, rank)) for d in dims]
        b = best_uniform_block(dims, Memory.abstract(mem))

        t0 = time.perf_counter()
        blocked = simulate_blocked(x, fs, 0, mem, b)
        dt_blocked = (time.perf_counter() - t0) * 1e6

        unblocked_words = bounds.seq_unblocked_cost(dims, rank)
        lb = bounds.seq_lb(dims, rank, mem)
        name = f"seq_blocked[{'x'.join(map(str, dims))},R{rank},M{mem}]"
        derived = (
            f"b={b};blocked_words={blocked.words};"
            f"unblocked_words={int(unblocked_words)};lb={lb:.0f};"
            f"blocked/lb={blocked.words / max(lb, 1):.2f};"
            f"unblocked/blocked={unblocked_words / blocked.words:.1f}"
        )
        out.append((name, dt_blocked, derived))
    return out
