"""Benchmark harness: one module per paper table/claim (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import sys

MODULES = (
    "seq_blocked",      # Thm 6.1: Alg 2 attains the sequential bounds
    "seq_vs_matmul",    # §VI-A: Alg 2 vs matmul-baseline regimes
    "par_comm",         # §VI-B + Thm 6.2: Alg 3/4 vs Cor 4.2 vs matmul
    "cp_als",           # §VII: dimension-tree reuse + CP-ALS e2e
    "all_mode",         # engine: dimtree vs independent all-mode MTTKRP
    "kernel_mttkrp",    # Pallas Alg-2 kernel: correctness + traffic model
    "lm_step",          # §Roofline: per-cell terms from the dry-run
)


def main() -> None:
    want = set(sys.argv[1:]) or set(MODULES)
    unknown = want - set(MODULES)
    if unknown:
        print(
            f"unknown benchmark module(s): {sorted(unknown)}; "
            f"available: {list(MODULES)}",
            file=sys.stderr,
        )
        sys.exit(2)
    print("name,us_per_call,derived")
    for modname in MODULES:
        if modname not in want:
            continue
        mod = __import__(f"benchmarks.{modname}", fromlist=["rows"])
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # a failing table must not kill the harness
            print(f"{modname}[ERROR],0.0,{type(e).__name__}:{e}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
