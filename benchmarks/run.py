"""Benchmark harness: one module per paper table/claim (DESIGN.md §7).

Default output is ``name,us_per_call,derived`` CSV on stdout. ``--json
PATH`` additionally writes a structured result file (schema-versioned,
stamped with ``--commit``/``--timestamp`` passed by the caller) — the
format the BENCH_*.json perf-trajectory files are built from.

``--context PATH`` runs the harness under a serialized
:class:`repro.ExecutionContext` (exported to ``REPRO_CONTEXT``, the seed
every driver's default path reads) and stamps that *ambient* context
JSON into every structured result row — a benchmark number without its
execution environment is not reproducible. Rows produced by modules that
deliberately pin a different fixed configuration for comparison (e.g.
``kernel_mttkrp``'s pallas rows, ``tune``'s per-backend timings) name
that configuration in their ``derived`` column; the recorded context is
the environment the *harness* ran under.

Usage:
    PYTHONPATH=src python -m benchmarks.run [module ...]
    PYTHONPATH=src python -m benchmarks.run --json out.json \\
        --commit "$(git rev-parse HEAD)" --timestamp "$(date -u +%s)" tune
    PYTHONPATH=src python -m benchmarks.run --context ctx.json --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

MODULES = (
    "seq_blocked",      # Thm 6.1: Alg 2 attains the sequential bounds
    "seq_vs_matmul",    # §VI-A: Alg 2 vs matmul-baseline regimes
    "par_comm",         # §VI-B + Thm 6.2: Alg 3/4 vs Cor 4.2 vs matmul
    "cp_als",           # §VII: dimension-tree reuse + CP-ALS e2e
    "all_mode",         # engine: dimtree vs independent all-mode MTTKRP
    "kernel_mttkrp",    # Pallas Alg-2 kernel: correctness + traffic model
    "tune",             # autotuner: search, warm-cache replay, calibration
    "tucker",           # Multi-TTM backends + Tucker/HOOI (arXiv:2207.10437)
    "lm_step",          # §Roofline: per-cell terms from the dry-run
    "serve",            # serving layer: batched vs looped, cold vs warm
)

JSON_SCHEMA_VERSION = 1


def collect(want: set[str]) -> list[dict]:
    """Run the selected modules, returning structured rows (errors become
    rows too — a failing table must not kill the harness).

    Each module runs under an in-memory :class:`repro.observe.Trace`
    (``capture="all"``: the harness itself is the opt-in), and every row
    it produced is stamped with that module's trace summary — modeled
    Eq-10 words, measured bytes where a collective sweep or bounds audit
    recorded one, and the resulting optimality ratio — so a BENCH row
    carries its traffic story next to its wall time.
    """
    from repro.observe import Trace, summarize_events

    rows: list[dict] = []
    for modname in MODULES:
        if modname not in want:
            continue
        try:  # import inside: a module broken at import time is one
            # [ERROR] row, not a dead harness
            mod = __import__(f"benchmarks.{modname}", fromlist=["rows"])
            with Trace() as tr:
                mod_rows = [
                    {"name": name, "us_per_call": us, "derived": str(derived)}
                    for name, us, derived in mod.rows()
                ]
            summary = summarize_events(tr.events)
            for row in mod_rows:
                row["trace"] = summary
            rows.extend(mod_rows)
        except Exception as e:
            rows.append(
                {
                    "name": f"{modname}[ERROR]",
                    "us_per_call": 0.0,
                    "derived": f"{type(e).__name__}:{e}",
                }
            )
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("modules", nargs="*", help=f"subset of {list(MODULES)}")
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="also write structured results to PATH (BENCH_*.json format)",
    )
    ap.add_argument(
        "--commit", default=None,
        help="commit id recorded in the JSON output (caller-provided)",
    )
    ap.add_argument(
        "--timestamp", default=None,
        help="timestamp recorded in the JSON output (caller-provided)",
    )
    ap.add_argument(
        "--context", metavar="PATH", default=None,
        help="run under this serialized repro.ExecutionContext (seeds "
        "REPRO_CONTEXT, the default every bare driver call reads) and "
        "record the ambient context in each JSON row",
    )
    args = ap.parse_args(argv)

    context_dict = None
    if args.context:
        from repro import ExecutionContext  # after PYTHONPATH=src

        ctx = ExecutionContext.load(args.context)  # validates eagerly
        context_dict = ctx.to_dict()
        os.environ["REPRO_CONTEXT"] = ctx.to_json()

    want = set(args.modules) or set(MODULES)
    unknown = want - set(MODULES)
    if unknown:
        print(
            f"unknown benchmark module(s): {sorted(unknown)}; "
            f"available: {list(MODULES)}",
            file=sys.stderr,
        )
        sys.exit(2)

    print("name,us_per_call,derived")
    sys.stdout.flush()
    rows = []
    for modname in MODULES:
        if modname not in want:
            continue
        for row in collect({modname}):
            rows.append(row)
            print(
                f"{row['name']},{row['us_per_call']:.1f},{row['derived']}"
            )
            sys.stdout.flush()

    if args.json:
        if context_dict is not None:
            for row in rows:  # every row records the ambient environment
                row["context"] = context_dict
        payload = {
            "schema": JSON_SCHEMA_VERSION,
            "commit": args.commit,
            "timestamp": args.timestamp,
            "modules": sorted(want),
            "context": context_dict,
            "results": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(rows)} results to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
