"""Benchmark: the autotuning subsystem end-to-end.

Three tables:

  * ``tune_search``  — cold empirical search per shape (all executors,
    perturbed plans, both 3-way kernel variants) and the winner.
  * ``tune_replay``  — warm-cache ``backend="auto"``: asserts the cache
    hit reproduces the tuned configuration *exactly* (no re-search), and
    times auto against every fixed backend — warm auto must never be
    slower than the worst fixed backend.
  * ``tune_calib``   — per-machine calibration: Eq-10 model bytes vs the
    HLO-measured bytes of the compiled blocked schedule for each shape
    (the model-vs-measured error report), plus the fitted
    bandwidth/overhead coefficients.

Runs against an isolated temporary plan cache (never the user's).
``REPRO_BENCH_TINY=1`` shrinks to one tiny shape for CI smoke runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

CASES = [
    ((48, 40, 32), 8),
    ((24, 20, 16, 8), 4),
]
TINY_CASES = [((16, 12, 8), 4)]


def _timed(fn, reps: int = 5) -> float:
    # best-of-5: these are microsecond-scale dispatch timings feeding the
    # perf-trajectory gate; best-of-2 lets a single GC pause poison a row
    jax.block_until_ready(fn())  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def rows() -> list[tuple[str, float, str]]:
    tiny = os.environ.get("REPRO_BENCH_TINY") == "1"
    cases = TINY_CASES if tiny else CASES
    out: list[tuple[str, float, str]] = []
    from repro.engine import execute
    from repro.tune.cache import default_cache, isolated_cache
    from repro.tune.calibrate import DEFAULT_CASES, calibrate
    from repro.tune.search import resolve, tune_mttkrp

    with isolated_cache():

        key = jax.random.PRNGKey(0)
        for dims, rank in cases:
            kx, *kf = jax.random.split(key, len(dims) + 1)
            x = jax.random.normal(kx, dims, jnp.float32)
            fs = [
                jax.random.normal(k, (d, rank), jnp.float32)
                for k, d in zip(kf, dims)
            ]
            name = f"{'x'.join(map(str, dims))},R{rank}"

            # cold search
            t0 = time.perf_counter()
            res = tune_mttkrp(x, fs, 0, interpret=True, reps=2)
            search_us = (time.perf_counter() - t0) * 1e6
            assert not res.cache_hit
            out.append(
                (
                    f"tune_search[{name}]",
                    search_us,
                    f"winner={res.winner.label};metric={res.metric};"
                    f"candidates={len(res.measurements)}",
                )
            )

            # warm replay: exact plan reproduction, no re-search
            r = resolve(x.shape, rank, 0, x.dtype, None)
            res2 = tune_mttkrp(x, fs, 0, interpret=True)
            plan_match = (
                r.cache_hit
                and res2.cache_hit
                and r.backend == res.winner.backend
                and r.plan == res.winner.plan
                and r.variant == res.winner.variant
                and r.block == res.winner.block
            )
            from repro import ExecutionContext

            # contexts hoisted out of the timed lambdas: construction/
            # validation must not bias the fixed-vs-auto comparison
            fixed_ctxs = {
                b: ExecutionContext.create(b, interpret=True)
                for b in ("einsum", "blocked_host", "pallas")
            }
            fixed_us = {
                b: _timed(
                    lambda c=c: execute.mttkrp(x, fs, 0, ctx=c)
                )
                for b, c in fixed_ctxs.items()
            }
            auto_ctx = ExecutionContext.create(backend="auto")
            auto_us = _timed(
                lambda: execute.mttkrp(x, fs, 0, ctx=auto_ctx)
            )
            worst = max(fixed_us.values())
            best = min(fixed_us.values())
            # the PR's acceptance invariants, enforced: a violation is an
            # [ERROR] row the CI smoke step fails on
            assert plan_match, (
                f"warm cache did not reproduce the tuned config for "
                f"{name}: {r} vs winner {res.winner}"
            )
            assert auto_us <= worst, (
                f"warm backend='auto' slower than the worst fixed backend "
                f"for {name}: {auto_us:.1f}us vs {fixed_us}"
            )
            out.append(
                (
                    f"tune_replay[{name}]",
                    auto_us,
                    f"hit={r.cache_hit};plan_match={plan_match};"
                    f"auto_us={auto_us:.1f};best_fixed_us={best:.1f};"
                    f"worst_fixed_us={worst:.1f};"
                    f"not_slower_than_worst={auto_us <= worst}",
                )
            )

        # calibration: model-vs-measured traffic error per shape
        cal_cases = DEFAULT_CASES[:3] if tiny else DEFAULT_CASES
        cal = calibrate(cal_cases, reps=2)
        for r in cal.rows:
            out.append(
                (
                    f"tune_calib[{'x'.join(map(str, r.shape))},R{r.rank}]",
                    r.walltime_us,
                    f"model_bytes={r.model_bytes};"
                    f"measured_bytes={r.measured_bytes};"
                    f"traffic_err={r.traffic_rel_err:+.1%};"
                    f"pred_us={r.predicted_us:.1f};"
                    f"time_err={r.time_rel_err:+.1%}",
                )
            )
        out.append(
            (
                "tune_calib[fit]",
                0.0,
                f"bandwidth_B_per_us={cal.bandwidth_bytes_per_us:.1f};"
                f"overhead_us={cal.overhead_us:.1f};"
                f"shapes={len(cal.rows)};backend={cal.backend}",
            )
        )
        out.append(
            (
                "tune_cache[entries]",
                0.0,
                f"path=isolated;entries={len(default_cache())}",
            )
        )
    return out
