"""Benchmark: CP-ALS end-to-end + dimension-tree reuse (§VII outlook).

Wall-time per sweep for plain per-mode MTTKRP vs the dimension tree, and
fit trajectories (both must match: the tree is exactly Gauss-Seidel ALS).
"""

from __future__ import annotations

import time

import jax

from repro.core.cp_als import cp_als
from repro.core.dimension_tree import dimtree_flops, naive_all_mode_flops
from repro.core.tensor import random_low_rank_tensor

CASES = [
    ((48, 48, 48), 8),
    ((32, 32, 32, 32), 6),
    ((96, 64, 32), 12),
]


def _time_als(x, rank, tree: bool) -> tuple[float, float]:
    t0 = time.perf_counter()
    res = cp_als(
        x, rank, n_iters=5, key=jax.random.PRNGKey(1),
        use_dimension_tree=tree,
    )
    jax.block_until_ready(res.factors[0])
    return (time.perf_counter() - t0) / 5, res.final_fit


def rows() -> list[tuple[str, float, str]]:
    out = []
    for dims, rank in CASES:
        x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
        t_plain, fit_plain = _time_als(x, rank, tree=False)
        t_tree, fit_tree = _time_als(x, rank, tree=True)
        model_naive = naive_all_mode_flops(dims, rank)
        model_tree = dimtree_flops(dims, rank)
        name = f"cp_als[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"fit={fit_plain:.4f};fit_tree={fit_tree:.4f};"
            f"tree_speedup={t_plain / max(t_tree, 1e-9):.2f}x;"
            f"modeled_flop_ratio={model_naive / max(model_tree, 1):.2f}"
        )
        out.append((name, t_tree * 1e6, derived))
    return out
