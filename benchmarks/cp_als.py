"""Benchmark: CP-ALS end-to-end + dimension-tree reuse (§VII outlook).

Wall-time per sweep for plain per-mode MTTKRP vs the dimension tree, and
fit trajectories (both must match: the tree is exactly Gauss-Seidel ALS).
Each case also reports the distributed-sweep communication model at P=64:
the Eq (12) sweep-optimal grid from ``distributed.grid_select`` and the
amortization ratio of one stationary ALS sweep vs N independent per-mode
Alg-3 calls (HLO-measured equivalents live in tests/dist_worker.py).
"""

from __future__ import annotations

import time

import jax

from repro.core.bounds import par_stationary_cost
from repro.core.cp_als import cp_als
from repro.core.dimension_tree import dimtree_flops, naive_all_mode_flops
from repro.core.tensor import random_low_rank_tensor
from repro.distributed.grid_select import (
    select_stationary_grid,
    stationary_sweep_words,
)

CASES = [
    ((48, 48, 48), 8),
    ((32, 32, 32, 32), 6),
    ((96, 64, 32), 12),
]

GRID_PROCS = 64


def _time_als(x, rank, tree: bool) -> tuple[float, float]:
    t0 = time.perf_counter()
    res = cp_als(
        x, rank, n_iters=5, key=jax.random.PRNGKey(1),
        use_dimension_tree=tree,
    )
    jax.block_until_ready(res.factors[0])
    return (time.perf_counter() - t0) / 5, res.final_fit


def rows() -> list[tuple[str, float, str]]:
    out = []
    for dims, rank in CASES:
        x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
        t_plain, fit_plain = _time_als(x, rank, tree=False)
        t_tree, fit_tree = _time_als(x, rank, tree=True)
        model_naive = naive_all_mode_flops(dims, rank)
        model_tree = dimtree_flops(dims, rank)
        choice = select_stationary_grid(dims, rank, GRID_PROCS, mode=None)
        # MTTKRP traffic only on both sides (neither baseline includes the
        # ALS solve's R^2 Gram collectives): the BHK amortization is 2/N
        sweep_w = stationary_sweep_words(
            dims, rank, choice.grid, include_solve_terms=False
        )
        indep_w = sum(
            par_stationary_cost(dims, rank, choice.grid, m)
            for m in range(len(dims))
        )
        name = f"cp_als[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"fit={fit_plain:.4f};fit_tree={fit_tree:.4f};"
            f"tree_speedup={t_plain / max(t_tree, 1e-9):.2f}x;"
            f"modeled_flop_ratio={model_naive / max(model_tree, 1):.2f};"
            f"grid_p{GRID_PROCS}={'x'.join(map(str, choice.grid))};"
            f"sweep_vs_indep_comm={sweep_w / max(indep_w, 1e-9):.2f}"
        )
        out.append((name, t_tree * 1e6, derived))
    return out
