"""Benchmark: CP-ALS end-to-end + dimension-tree reuse (§VII outlook).

Wall-time per sweep for plain per-mode MTTKRP vs the dimension tree, and
fit trajectories (both must match: the tree is exactly Gauss-Seidel ALS).
Each case also reports the distributed-sweep communication model at P=64:
the Eq (12) sweep-optimal grid from ``distributed.grid_select`` and the
amortization ratio of one stationary ALS sweep vs N independent per-mode
Alg-3 calls (HLO-measured equivalents live in tests/dist_worker.py).

The ``cp_als_sweep[...]`` rows are the fused-sweep success metric: sweep
walltime under ``sweep="fused"`` (the arXiv:1708.08976 mode-reuse
schedule, 2 tensor passes) vs ``sweep="per_mode"`` (N passes), plus the
fused sweep under the bf16 ``compute_dtype`` policy.  Both timings warm
the dispatch caches first so the comparison is steady-state walltime.

``REPRO_BENCH_TINY=1`` shrinks to one tiny shape for CI smoke runs.
"""

from __future__ import annotations

import os
import time

import jax

from repro.core.bounds import par_stationary_cost
from repro.core.cp_als import cp_als
from repro.core.dimension_tree import dimtree_flops, naive_all_mode_flops
from repro.core.tensor import random_low_rank_tensor
from repro.distributed.grid_select import (
    select_stationary_grid,
    stationary_sweep_words,
)
from repro.engine.context import ExecutionContext

CASES = [
    ((48, 48, 48), 8),
    ((32, 32, 32, 32), 6),
    ((96, 64, 32), 12),
]

GRID_PROCS = 64


def _time_als(x, rank, tree: bool) -> tuple[float, float]:
    t0 = time.perf_counter()
    res = cp_als(
        x, rank, n_iters=5, key=jax.random.PRNGKey(1),
        use_dimension_tree=tree,
    )
    jax.block_until_ready(res.factors[0])
    return (time.perf_counter() - t0) / 5, res.final_fit


def _time_sweep(x, rank, sweep: str, ctx=None, n_iters=5, reps=3):
    """Steady-state per-sweep walltime under one sweep schedule.

    Best-of-``reps``: these rows feed the perf-trajectory gate's
    fused-speedup floor, so a single scheduler hiccup must not flip the
    recorded winner."""
    kw = {"key": jax.random.PRNGKey(1), "sweep": sweep}
    if ctx is not None:
        kw["ctx"] = ctx
    cp_als(x, rank, n_iters=1, **kw)  # warm dispatch/jit caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = cp_als(x, rank, n_iters=n_iters, **kw)
        jax.block_until_ready(res.factors[0])
        best = min(best, (time.perf_counter() - t0) / n_iters)
    return best, res.final_fit


def rows() -> list[tuple[str, float, str]]:
    tiny = os.environ.get("REPRO_BENCH_TINY") == "1"
    cases = [((16, 16, 16), 4)] if tiny else CASES
    out = []
    for dims, rank in cases:
        x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
        t_plain, fit_plain = _time_als(x, rank, tree=False)
        t_tree, fit_tree = _time_als(x, rank, tree=True)
        model_naive = naive_all_mode_flops(dims, rank)
        model_tree = dimtree_flops(dims, rank)
        choice = select_stationary_grid(dims, rank, GRID_PROCS, mode=None)
        # MTTKRP traffic only on both sides (neither baseline includes the
        # ALS solve's R^2 Gram collectives): the BHK amortization is 2/N
        sweep_w = stationary_sweep_words(
            dims, rank, choice.grid, include_solve_terms=False
        )
        indep_w = sum(
            par_stationary_cost(dims, rank, choice.grid, m)
            for m in range(len(dims))
        )
        name = f"cp_als[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"fit={fit_plain:.4f};fit_tree={fit_tree:.4f};"
            f"tree_speedup={t_plain / max(t_tree, 1e-9):.2f}x;"
            f"modeled_flop_ratio={model_naive / max(model_tree, 1):.2f};"
            f"grid_p{GRID_PROCS}={'x'.join(map(str, choice.grid))};"
            f"sweep_vs_indep_comm={sweep_w / max(indep_w, 1e-9):.2f}"
        )
        out.append((name, t_tree * 1e6, derived))

    # fused-sweep success metric: mode-reuse schedule (2 tensor passes)
    # vs per-mode dispatch (N passes), same driver, steady-state walltime
    # per sweep.  Backend pinned to einsum so the comparison isolates the
    # schedule (named in derived, per the harness convention); the last
    # case is sized so the tensor passes dominate the Gram/solve work.
    sweep_cases = (
        [((16, 16, 16), 4)] if tiny else CASES + [((96, 96, 96), 16)]
    )
    for dims, rank in sweep_cases:
        x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
        ctx = ExecutionContext.create(backend="einsum")
        t_pm, fit_pm = _time_sweep(x, rank, "per_mode", ctx=ctx)
        t_fu, fit_fu = _time_sweep(x, rank, "fused", ctx=ctx)
        ctx_bf16 = ExecutionContext.create(
            backend="einsum", compute_dtype="bfloat16"
        )
        t_bf, _ = _time_sweep(x, rank, "fused", ctx=ctx_bf16)
        sweep_name = f"cp_als_sweep[{'x'.join(map(str, dims))},R{rank}]"
        sweep_derived = (
            f"backend=einsum;t_per_mode_us={t_pm * 1e6:.1f};"
            f"fused_speedup={t_pm / max(t_fu, 1e-9):.2f}x;"
            f"t_fused_bf16_us={t_bf * 1e6:.1f};"
            f"fit_per_mode={fit_pm:.4f};fit_fused={fit_fu:.4f}"
        )
        out.append((sweep_name, t_fu * 1e6, sweep_derived))
    return out
