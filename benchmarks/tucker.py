"""Benchmark: Multi-TTM backends + Tucker/HOOI end-to-end (arXiv:2207.10437).

Per case: wall time of one full-core Multi-TTM through each engine
backend (einsum, the uniform-b blocked host schedule, the Pallas
Kronecker kernel in interpret mode off-TPU), the planner's modeled
traffic vs the blocked-cost oracle, Tucker/HOOI wall time per sweep, and
the distributed sweep model (Multi-TTM-sweep-optimal grid from
``distributed.grid_select`` and its per-processor words — the
HLO-measured equivalent lives in tests/dist_worker.py).

``REPRO_BENCH_TINY=1`` shrinks to one tiny shape for CI smoke runs.
"""

from __future__ import annotations

import os
import time

import jax

import repro
from repro.core.bounds import multi_ttm_blocked_cost
from repro.core.tensor import random_tucker_tensor
from repro.distributed.grid_select import (
    multi_ttm_sweep_words,
    select_tucker_grid,
)
from repro.engine.plan import Memory, uniform_multi_ttm_plan

CASES = [
    ((48, 48, 48), (8, 6, 4)),
    ((32, 32, 32, 32), (4, 4, 4, 4)),
    ((96, 64, 32), (12, 8, 6)),
]
TINY_CASES = [((12, 10, 8), (4, 3, 2))]

GRID_PROCS = 64
MEM_WORDS = 4096


def _time_call(fn, reps: int = 3) -> float:
    jax.block_until_ready(fn())  # warmup/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def rows() -> list[tuple[str, float, str]]:
    tiny = os.environ.get("REPRO_BENCH_TINY") == "1"
    cases = TINY_CASES if tiny else CASES
    out = []
    for dims, ranks in cases:
        tag = "x".join(map(str, dims)) + "_R" + "x".join(map(str, ranks))
        x, _, _ = random_tucker_tensor(jax.random.PRNGKey(0), dims, ranks)
        mats = [
            jax.random.normal(jax.random.PRNGKey(k + 1), (d, r))
            for k, (d, r) in enumerate(zip(dims, ranks))
        ]
        backends = {
            "einsum": repro.ExecutionContext.create(backend="einsum"),
            "blocked_host": repro.ExecutionContext.create(
                backend="blocked_host"
            ),
            "pallas": repro.ExecutionContext.create(
                backend="pallas", interpret=True
            ),
        }
        for name, ctx in backends.items():
            us = _time_call(lambda c=ctx: repro.multi_ttm(x, mats, ctx=c))
            out.append((f"multi_ttm[{tag}][{name}]", us, "core"))
        # planner vs oracle: the uniform-b model is pinned exact
        plan = uniform_multi_ttm_plan(dims, ranks[1:], Memory.abstract(
            MEM_WORDS
        ))
        model = plan.model_words(dims)
        oracle = multi_ttm_blocked_cost(dims, ranks[1:], plan.block_i)
        out.append((
            f"multi_ttm_model[{tag}]", 0.0,
            f"b={plan.block_i} model_words={model} oracle={oracle:.0f} "
            f"M={MEM_WORDS}",
        ))
        # Tucker/HOOI end-to-end
        n_iters = 2 if tiny else 4
        t0 = time.perf_counter()
        res = repro.tucker_hooi(x, ranks, n_iters=n_iters)
        jax.block_until_ready(res.core)
        out.append((
            f"tucker_hooi[{tag}]",
            (time.perf_counter() - t0) / n_iters * 1e6,
            f"fit={res.final_fit:.5f}",
        ))
        # distributed sweep model at P=GRID_PROCS
        choice = select_tucker_grid(dims, ranks, GRID_PROCS)
        out.append((
            f"tucker_grid[{tag}][P={GRID_PROCS}]", 0.0,
            f"grid={choice.grid} sweep_words="
            f"{multi_ttm_sweep_words(dims, ranks, choice.grid):.0f}",
        ))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
