"""Perf-trajectory gate: compare two BENCH_*.json files and fail on
regression beyond a noise threshold.

The committed ``BENCH_*.json`` files form the repo's performance
trajectory (one per recorded run, named by date).  This gate holds the
line: given an older and a newer result file it compares every row
present in BOTH by name and fails when the newer ``us_per_call`` exceeds
the older by more than ``--threshold`` (relative; default 0.5 — CI
machines are noisy, the gate is for step-function regressions, not
percent-level drift).  Error rows (``name`` ending in ``[ERROR]``) in
the newer file always fail.

``--min-fused-speedup`` additionally asserts a per-row floor on the
fused-sweep success metric: every ``cp_als_sweep[...]`` row in the newer
file must report ``fused_speedup=<x>x`` at or above it (0.9 in CI —
the marginal asymmetric shapes sit at parity within noise, and the floor
catches the fused path becoming genuinely slower).
``--require-fused-win`` asserts the headline criterion on top: at least
one sweep row must beat 1x (the mode-reuse schedule keeps beating the
per-mode dispatch it replaced somewhere).

``--traffic-threshold`` gates the observability columns too: rows
stamped with a ``trace`` summary (``benchmarks.run`` runs every module
under a :class:`repro.observe.Trace`) must not grow their modeled Eq-10
words or worsen their measured/modeled optimality ratio beyond it.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_gate OLD.json NEW.json \\
        [--threshold 0.5] [--min-fused-speedup 0.9] [--require-fused-win]
    PYTHONPATH=src python -m benchmarks.perf_gate          # auto-discover

With no positional files the gate discovers the committed trajectory
itself: the two newest ``BENCH_*.json`` under ``--bench-dir`` (default:
the current directory).  Fewer than two such files is not an error — a
young repo (or a fresh fork) has no trajectory to hold yet, so the gate
prints what it found and exits 0.

Exit status 0 = gate passes (or nothing to compare yet); 1 = regressions
(one line per violation on stderr); 2 = bad invocation / unreadable
input.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

_SPEEDUP_RE = re.compile(r"fused_speedup=([0-9.]+)x")


def load_bench(path: str) -> dict[str, dict]:
    """Load one BENCH json into ``{row name: row}`` (latest wins on
    duplicate names)."""
    with open(path) as f:
        payload = json.load(f)
    rows = payload.get("results", [])
    if not isinstance(rows, list):
        raise ValueError(f"{path}: 'results' is not a list")
    return {r["name"]: r for r in rows if isinstance(r, dict) and "name" in r}


def compare_traffic(
    old: dict[str, dict],
    new: dict[str, dict],
    *,
    traffic_threshold: float = 0.25,
) -> list[str]:
    """Gate the observability columns: rows in BOTH files carrying a
    ``trace`` summary (stamped by ``benchmarks.run``) must not grow their
    modeled Eq-10 words — or worsen their measured/modeled optimality
    ratio — by more than ``traffic_threshold`` (relative).  The traffic
    model is deterministic, so this tolerance is for benign plan changes,
    not machine noise; rows lacking a trace on either side are skipped
    (pre-observability baselines stay comparable)."""
    violations: list[str] = []
    for name in sorted(set(old) & set(new)):
        t_old, t_new = old[name].get("trace"), new[name].get("trace")
        if not isinstance(t_old, dict) or not isinstance(t_new, dict):
            continue
        for field in ("modeled_words", "optimality_ratio"):
            v_old, v_new = t_old.get(field), t_new.get(field)
            if not v_old or v_new is None:
                continue  # no baseline (or measured side) to regress
            ratio = float(v_new) / float(v_old)
            if ratio > 1.0 + traffic_threshold:
                violations.append(
                    f"{name}: {field} {float(v_new):.1f} vs "
                    f"{float(v_old):.1f} baseline ({ratio:.2f}x > "
                    f"{1.0 + traffic_threshold:.2f}x allowed)"
                )
    return violations


def compare_bench(
    old: dict[str, dict],
    new: dict[str, dict],
    *,
    threshold: float = 0.5,
    min_fused_speedup: float | None = None,
    require_fused_win: bool = False,
) -> list[str]:
    """Return one violation string per gate failure (empty = pass).

    Rows only in one file are ignored (benchmarks come and go); the gate
    is about rows whose history continues.
    """
    violations: list[str] = []
    for name, row in sorted(new.items()):
        if name.endswith("[ERROR]"):
            violations.append(f"{name}: errored: {row.get('derived', '')}")
    for name in sorted(set(old) & set(new)):
        if name.endswith("[ERROR]"):
            continue
        t_old = float(old[name].get("us_per_call", 0.0))
        t_new = float(new[name].get("us_per_call", 0.0))
        if t_old <= 0.0:
            continue  # no baseline to regress against
        ratio = t_new / t_old
        if ratio > 1.0 + threshold:
            violations.append(
                f"{name}: {t_new:.1f}us vs {t_old:.1f}us baseline "
                f"({ratio:.2f}x > {1.0 + threshold:.2f}x allowed)"
            )
    if min_fused_speedup is not None or require_fused_win:
        sweep_rows = [n for n in new if n.startswith("cp_als_sweep[")]
        if not sweep_rows:
            violations.append(
                "no cp_als_sweep[...] rows in the newer file (the fused-"
                "sweep success metric is unrecorded)"
            )
        speedups: list[float] = []
        for name in sorted(sweep_rows):
            m = _SPEEDUP_RE.search(str(new[name].get("derived", "")))
            if m is None:
                violations.append(f"{name}: derived lacks fused_speedup=")
                continue
            s = float(m.group(1))
            speedups.append(s)
            if min_fused_speedup is not None and s < min_fused_speedup:
                violations.append(
                    f"{name}: fused_speedup={m.group(1)}x "
                    f"< required {min_fused_speedup}x"
                )
        if require_fused_win and speedups and max(speedups) < 1.0:
            violations.append(
                f"no cp_als_sweep row beats per-mode (best fused_speedup "
                f"{max(speedups)}x < 1.0x)"
            )
    return violations


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.perf_gate", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("old", nargs="?", default=None,
                    help="baseline BENCH_*.json (earlier run); omit both "
                         "positionals to auto-discover from --bench-dir")
    ap.add_argument("new", nargs="?", default=None,
                    help="candidate BENCH_*.json (newer run)")
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding the committed BENCH_*.json "
                         "trajectory (used when old/new are omitted)")
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="relative walltime growth allowed (default 0.5)")
    ap.add_argument("--min-fused-speedup", type=float, default=None,
                    help="per-row floor for fused_speedup in cp_als_sweep "
                         "rows")
    ap.add_argument("--require-fused-win", action="store_true",
                    help="at least one cp_als_sweep row must beat 1x")
    ap.add_argument("--traffic-threshold", type=float, default=None,
                    help="also gate the stamped trace summaries: relative "
                         "growth allowed in modeled words / optimality "
                         "ratio for rows traced in both files")
    args = ap.parse_args(argv)
    if (args.old is None) != (args.new is None):
        print(
            "perf_gate: pass both OLD and NEW files, or neither "
            "(auto-discovery)", file=sys.stderr,
        )
        return 2
    if args.old is None:
        files = sorted(
            glob.glob(os.path.join(args.bench_dir, "BENCH_*.json"))
        )
        if len(files) < 2:
            found = ", ".join(os.path.basename(f) for f in files) or "none"
            print(
                f"perf_gate: skipped — found {len(files)} BENCH_*.json "
                f"in {args.bench_dir!r} ({found}); a trajectory needs "
                f"two. Record a second run with benchmarks.run to arm "
                f"the gate."
            )
            return 0
        args.old, args.new = files[-2], files[-1]
    try:
        old = load_bench(args.old)
        new = load_bench(args.new)
    except (OSError, ValueError, json.JSONDecodeError, KeyError) as e:
        print(f"perf_gate: cannot read inputs: {e}", file=sys.stderr)
        return 2
    violations = compare_bench(
        old, new, threshold=args.threshold,
        min_fused_speedup=args.min_fused_speedup,
        require_fused_win=args.require_fused_win,
    )
    if args.traffic_threshold is not None:
        violations += compare_traffic(
            old, new, traffic_threshold=args.traffic_threshold
        )
    common = len(set(old) & set(new))
    if violations:
        for v in violations:
            print(f"PERF REGRESSION: {v}", file=sys.stderr)
        print(
            f"perf_gate: {len(violations)} violation(s) over {common} "
            f"common row(s)", file=sys.stderr,
        )
        return 1
    print(f"perf_gate: OK ({common} common row(s) within threshold)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
