"""Benchmark: dimension-tree vs independent all-mode MTTKRP (the engine's
reuse win, §VII / Hayashi et al. arXiv:1708.08976).

Wall-time per full all-mode sweep through the engine for both methods, on
both the einsum backend and the Pallas kernels (interpret mode on CPU —
relative numbers; on TPU the same harness times Mosaic). The modeled flop
ratio comes from the exact dimension-tree cost model.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.dimension_tree import dimtree_flops, naive_all_mode_flops
from repro.engine import all_mode_mttkrp

CASES = [
    ((48, 48, 48), 16),
    ((32, 32, 32, 32), 8),
    ((24, 24, 24, 24, 24), 6),
]


def _time(fn, reps: int = 3) -> float:
    # best-of-reps, not mean: these rows feed the perf-trajectory gate,
    # and one scheduler hiccup in a mean poisons the recorded walltime
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def rows() -> list[tuple[str, float, str]]:
    out = []
    key = jax.random.PRNGKey(0)
    for dims, rank in CASES:
        kx, *kf = jax.random.split(key, len(dims) + 1)
        x = jax.random.normal(kx, dims, jnp.float32)
        fs = [
            jax.random.normal(k, (d, rank), jnp.float32)
            for k, d in zip(kf, dims)
        ]
        t_ind = _time(lambda: all_mode_mttkrp(x, fs, method="independent"))
        t_tree = _time(lambda: all_mode_mttkrp(x, fs, method="dimtree"))
        # kernel-backed tree (interpret mode: schedule correctness + CPU time)
        from repro import ExecutionContext

        pal_ctx = ExecutionContext.create(backend="pallas", interpret=True)
        t_tree_pal = _time(
            lambda: all_mode_mttkrp(x, fs, method="dimtree", ctx=pal_ctx),
            reps=1,
        )
        a = all_mode_mttkrp(x, fs, method="dimtree")
        b = all_mode_mttkrp(x, fs, method="independent")
        err = max(
            float(jnp.max(jnp.abs(u - v))) / (float(jnp.max(jnp.abs(v))) + 1e-30)
            for u, v in zip(a, b)
        )
        model_ratio = naive_all_mode_flops(dims, rank) / max(
            dimtree_flops(dims, rank), 1
        )
        name = f"all_mode[{'x'.join(map(str, dims))},R{rank}]"
        derived = (
            f"tree_speedup={t_ind / max(t_tree, 1e-9):.2f}x;"
            f"modeled_flop_ratio={model_ratio:.2f};"
            f"relerr={err:.2e};"
            f"t_tree_pallas_us={t_tree_pal * 1e6:.0f}"
        )
        out.append((name, t_tree * 1e6, derived))
    return out
