"""Benchmark: the serving layer's amortization claims, measured.

Three tables:

  * ``serve_batched_B{b}`` / ``serve_looped_B{b}`` — requests/sec of ONE
    batched ``cp_als_batched`` call on a B-stack vs a Python loop of B
    single ``cp_als`` calls (same inits, warm programs). The batched
    path pays plan resolution and dispatch once per sweep-mode instead
    of once per request — the Eq-9/10 amortization argument applied to
    launch overhead; at B>=4 batched must be strictly faster.
  * ``serve_queue_B{b}`` — end-to-end ``DecompositionServer`` flush
    (bucketing + padding + batched execute) in requests/sec.
  * ``serve_cold_compile`` / ``serve_warm_compile`` — the persistent
    compilation cache (``ExecutionContext.compilation_cache``): a fresh
    subprocess jit-compiles the bucket's batched program against an
    empty cache directory (cold), a second fresh subprocess compiles the
    identical program against the now-populated directory (warm; XLA
    reloads from disk). Warm must be faster than cold.

``REPRO_BENCH_TINY=1`` shrinks shapes/batches for CI smoke runs.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

SHAPE, RANK, ITERS = (16, 14, 12), 4, 5
BATCHES = (1, 2, 4, 8)
TINY_SHAPE, TINY_BATCHES = (10, 8, 6), (1, 4)

_CHILD = """
import json, sys, time
import jax, jax.numpy as jnp
from repro.engine.batch import cp_als_batched
from repro.engine.context import ExecutionContext

cache_dir, b, shape, rank, iters = (
    sys.argv[1], int(sys.argv[2]), tuple(json.loads(sys.argv[3])),
    int(sys.argv[4]), int(sys.argv[5]),
)
ctx = ExecutionContext.create(compilation_cache=cache_dir)
ctx.ensure_compilation_cache()
x = jax.random.normal(jax.random.PRNGKey(0), (b,) + shape)
# tol=0: no per-iteration concretization, so the whole batched run is
# one traceable (and therefore persistently cacheable) program
run = jax.jit(lambda t: cp_als_batched(t, rank, n_iters=iters).weights)
t0 = time.perf_counter()
jax.block_until_ready(run(x))
print(json.dumps({"first_call_s": time.perf_counter() - t0}))
"""


def _timed(fn, reps: int = 3) -> float:
    jax.block_until_ready(fn())  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def _compile_seconds(cache_dir: str, b: int, shape, rank, iters) -> float:
    """First-call seconds of the bucket's jitted batched program in a
    FRESH process pointed at ``cache_dir`` (subprocess: compilation
    caches are process-global, so cold/warm needs process isolation)."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, cache_dir, str(b),
         json.dumps(list(shape)), str(rank), str(iters)],
        capture_output=True, text=True, env=env, check=True,
    )
    return float(json.loads(out.stdout.strip().splitlines()[-1])["first_call_s"])


def rows() -> list[tuple[str, float, str]]:
    tiny = os.environ.get("REPRO_BENCH_TINY") == "1"
    shape = TINY_SHAPE if tiny else SHAPE
    batches = TINY_BATCHES if tiny else BATCHES
    iters = 3 if tiny else ITERS
    out: list[tuple[str, float, str]] = []

    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_factors
    from repro.engine.batch import cp_als_batched
    from repro.launch.serve import DecompositionServer

    key = jax.random.PRNGKey(0)
    for b in batches:
        x = jax.random.normal(key, (b,) + shape)
        keys = jax.random.split(jax.random.PRNGKey(1), b)
        inits = [
            jnp.stack(f) for f in zip(*[
                random_factors(k, shape, RANK, x.dtype) for k in keys
            ])
        ]

        us_batched = _timed(lambda: cp_als_batched(
            x, RANK, n_iters=iters, init_factors=inits
        ).weights)
        us_looped = _timed(lambda: [
            cp_als(
                x[i], RANK, n_iters=iters,
                init_factors=[f[i] for f in inits],
            ).weights
            for i in range(b)
        ][-1])
        speedup = us_looped / us_batched
        out.append((
            f"serve_batched_B{b}", us_batched,
            f"req_per_s={b / (us_batched * 1e-6):.1f} "
            f"batched_speedup={speedup:.2f}x",
        ))
        out.append((
            f"serve_looped_B{b}", us_looped,
            f"req_per_s={b / (us_looped * 1e-6):.1f}",
        ))

        def queue_flush(xb=x, b=b):
            srv = DecompositionServer(n_iters=iters, tol=0.0)
            for i in range(b):
                srv.submit(xb[i], RANK, request_id=f"r{i}")
            return jnp.asarray(
                [r.fit for r in srv.flush().values()]
            )

        us_queue = _timed(queue_flush)
        out.append((
            f"serve_queue_B{b}", us_queue,
            f"req_per_s={b / (us_queue * 1e-6):.1f}",
        ))

    # cold vs warm persistent-compile split (fresh process each side)
    b = batches[-1]
    with tempfile.TemporaryDirectory() as cache_dir:
        cold_s = _compile_seconds(cache_dir, b, shape, RANK, iters)
        warm_s = _compile_seconds(cache_dir, b, shape, RANK, iters)
    out.append((
        "serve_cold_compile", cold_s * 1e6,
        f"B={b} empty persistent cache",
    ))
    out.append((
        "serve_warm_compile", warm_s * 1e6,
        f"B={b} warm_speedup={cold_s / warm_s:.2f}x",
    ))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
