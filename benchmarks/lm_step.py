"""Benchmark: per-(arch × shape × mesh) roofline terms from the dry-run
records (results/dryrun/*.json) — the §Roofline table source.

Emits one row per completed cell: the three terms (seconds), bottleneck,
and MODEL_FLOPS/HLO_FLOPs useful-compute ratio. Cells not yet swept are
skipped (run ``python -m repro.launch.dryrun --all`` first).
"""

from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import roofline_from_record

RESULTS = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "results", "dryrun"),
)


def rows() -> list[tuple[str, float, str]]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        cell = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        if rec.get("status") == "skipped":
            out.append((f"roofline[{cell}]", 0.0, "skipped=" + rec["reason"][:60]))
            continue
        if rec.get("status") != "ok":
            out.append((f"roofline[{cell}]", 0.0, "status=error"))
            continue
        rt = roofline_from_record(rec)
        mem_gib = rec["memory"]["peak_bytes_est"] / 2 ** 30
        derived = (
            f"bottleneck={rt.bottleneck};t_comp={rt.t_compute:.3e};"
            f"t_mem={rt.t_memory:.3e};t_coll={rt.t_collective:.3e};"
            f"useful={rt.useful_ratio:.2f};mem_gib={mem_gib:.1f}"
        )
        out.append(
            (f"roofline[{cell}]", rt.step_time_overlapped * 1e6, derived)
        )
    if not out:
        out.append(("roofline[no-dryrun-results]", 0.0, "run dryrun --all"))
    return out
